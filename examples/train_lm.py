"""End-to-end training driver: a ~100M-param qwen-family model for a few
hundred steps on the deterministic synthetic stream, with checkpointing.

(The assignment's end-to-end requirement; sized to be CPU-feasible by
default -- pass --full100m on a real machine for the 100M config.)

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--full100m]
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.models.registry import make_arch  # noqa: E402
from repro.models.transformer import param_count  # noqa: E402
from repro.parallel.mesh import make_host_mesh  # noqa: E402
from repro.train import optim  # noqa: E402
from repro.train.data import SyntheticLM  # noqa: E402
from repro.train.loop import train  # noqa: E402

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--full100m", action="store_true",
                help="12L x 768d x 32k-vocab (~100M params); default is a "
                     "CPU-sized model")
ap.add_argument("--ckpt-dir", default="ckpts/train_lm_example")
args = ap.parse_args()

cfg = get_config("qwen1.5-0.5b", reduced=True)
if args.full100m:
    cfg = dataclasses.replace(
        cfg, n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
        head_dim=64, d_ff=2048, vocab_size=32000)
arch = make_arch(cfg)
n = param_count(jax.eval_shape(lambda: arch.init(jax.random.PRNGKey(0))))
print(f"# training {cfg.name}: {n/1e6:.1f}M params, {args.steps} steps")

data = SyntheticLM(cfg.vocab_size, batch=8, seq_len=64, seed=0)
optimizer = optim.adamw(
    optim.warmup_cosine(3e-3, args.steps // 20 + 1, args.steps),
    weight_decay=0.0)
state, history = train(arch, optimizer, make_host_mesh(1, 1), data,
                       steps=args.steps, ckpt_dir=args.ckpt_dir,
                       ckpt_every=100, log_every=10)
print(f"# done: loss {history[0]:.3f} -> {history[-1]:.3f} "
      f"(checkpoints in {args.ckpt_dir}; rerun resumes automatically)")
