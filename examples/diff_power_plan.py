"""Differentiable CRRM: gradient-ascend a power plan through the engine.

The RL loop in ``examples/rl_power_control.py`` treats the simulator as
a black box; here we open it.  Built with a
``repro.sim.radio.RelaxConfig``, the scan-compiled MAC engine is
differentiable end to end -- argmax attachment becomes a temperature
softmax over log-RSRP, the CQI staircase a sigmoid-sum surrogate, the
schedulers' segment reductions plain (autodiff-able) scatters -- so
``jax.grad`` of an episode's served throughput with respect to the
*power-action trajectory* is exact for the relaxed program and within
1e-3 of finite differences (tests/test_rl.py).

``repro.rl.diffopt`` packages that into first-order planning: Adam
ascent on the relaxed objective, scored every few steps on the exact
(un-relaxed) engine so the printed trajectory is real simulator
throughput, not the surrogate.  Tens of gradient steps find a plan that
PPO needs hundreds of episodes to approach -- the case for
differentiable system-level simulation.

Run:  PYTHONPATH=src python examples/diff_power_plan.py
"""
from repro.core.crrm import CRRM
from repro.rl import diffopt
from repro.sim.scenarios import make_scenario

sim = CRRM(make_scenario("dense_urban", n_ues=12,
                         traffic_params=dict(arrival_rate_hz=2000.0,
                                             packet_size_bits=12_000.0)))

res = diffopt.optimize_power_plan(
    sim,
    n_segments=4,        # the plan: 4 power matrices, 10 TTIs each
    tti_per_segment=10,
    steps=40, lr=0.2,
    score_every=5, verbose=True)

first, last = res.history[0], res.history[-1]
print(f"\nexact-engine served throughput: {first['hard_mbps']:.3f} -> "
      f"{last['hard_mbps']:.3f} Mbit/s over {last['step']} gradient "
      f"steps")
print("per-segment per-cell power totals (W):")
for i, seg in enumerate(res.power_plan.sum(-1)):
    print(f"  seg {i}: " + " ".join(f"{float(p):.2f}" for p in seg))
