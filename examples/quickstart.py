"""CRRM quickstart: build a named scenario from the registry, inspect KPIs,
move some UEs and watch the smart update do row-local work.

Scenarios are the reproducible way to define a task: a preset name plus
overrides reconstructs the exact ``CRRM_parameters`` anywhere
(``sim/scenarios.py``).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.crrm import CRRM
from repro.sim.scenarios import make_scenario, scenario_description

# a 7-site tri-sector interference-limited microcell network: the
# "dense_urban" preset, shrunk a little and reseeded -- overrides keep the
# preset's identity (carrier, fading, scheduler) while adapting its scale
params = make_scenario(
    "dense_urban",
    n_ues=120,
    n_cells=21,                 # 7 hex sites x 3 sectors
    seed=7,
)
print(f"scenario dense_urban: {scenario_description('dense_urban')}")
sim = CRRM(params)

tput = np.asarray(sim.get_UE_throughputs()) / 1e6
sinr = np.asarray(sim.get_SINR_dB()).max(axis=1)
print(f"network: {sim.n_ues} UEs x {sim.n_cells} cells "
      f"({params.n_sectors}-sector), {params.n_subbands} subbands x "
      f"{params.n_rb_subbands} CQI subbands")
print(f"median throughput {np.median(tput):6.1f} Mb/s   "
      f"cell-edge (p5) {np.percentile(tput, 5):5.1f} Mb/s")
print(f"median SINR       {np.median(sinr):6.1f} dB")

# move 10% of UEs: only those rows recompute (the paper's smart update)
moved = np.arange(12)
sim.move_UEs(moved, np.column_stack([
    np.random.default_rng(0).uniform(0, params.extent_m, (12, 2)),
    np.full((12, 1), params.h_ut_m)]).astype(np.float32))
tput2 = np.asarray(sim.get_UE_throughputs()) / 1e6
print(f"after moving {len(moved)} UEs: median {np.median(tput2):6.1f} Mb/s")
print("node update counts (full, row):")
for name, counts in sim.update_counts().items():
    if counts != (0, 0):
        print(f"  {name:8s} {counts}")
print("note Shannon=(0,0): compute-on-demand never touched what you "
      "didn't query.")

# run a short compiled episode with in-scan KPI telemetry: the scan emits
# a per-TTI Telemetry pytree alongside the trajectory (structurally free
# when off -- same compiled program, bit-identical throughput)
from repro.obs import format_summary, summarize

tput_ep, telem = sim.run_episode(n_tti=50, telemetry=True)
print("\n50-TTI episode KPIs (repro.obs telemetry):")
print(format_summary(summarize(telem, tti_s=params.tti_s)))
