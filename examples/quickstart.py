"""CRRM quickstart: build a 7-site tri-sector network, inspect KPIs, move
some UEs and watch the smart update do row-local work.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.crrm import CRRM
from repro.core.params import CRRM_parameters

params = CRRM_parameters(
    n_ues=120,
    n_cells=21,                 # 7 hex sites x 3 sectors
    n_sectors=3,
    n_subbands=2,
    pathloss_model_name="UMa",  # strategy pattern: try "RMa", "UMi", ...
    power_W=20.0,
    bandwidth_Hz=20e6,
    fairness_p=0.5,
    seed=7,
)
sim = CRRM(params)

tput = np.asarray(sim.get_UE_throughputs()) / 1e6
sinr = np.asarray(sim.get_SINR_dB()).max(axis=1)
print(f"network: {sim.n_ues} UEs x {sim.n_cells} cells "
      f"({params.n_sectors}-sector), {params.n_subbands} subbands")
print(f"median throughput {np.median(tput):6.1f} Mb/s   "
      f"cell-edge (p5) {np.percentile(tput, 5):5.1f} Mb/s")
print(f"median SINR       {np.median(sinr):6.1f} dB")

# move 10% of UEs: only those rows recompute (the paper's smart update)
moved = np.arange(12)
sim.move_UEs(moved, np.column_stack([
    np.random.default_rng(0).uniform(0, 3000, (12, 2)),
    np.full((12, 1), 1.5)]).astype(np.float32))
tput2 = np.asarray(sim.get_UE_throughputs()) / 1e6
print(f"after moving {len(moved)} UEs: median {np.median(tput2):6.1f} Mb/s")
print("node update counts (full, row):")
for name, counts in sim.update_counts().items():
    if counts != (0, 0):
        print(f"  {name:8s} {counts}")
print("note Shannon=(0,0): compute-on-demand never touched what you "
      "didn't query.")
