"""Paper example 13: smart update vs full recalculation (the x2 claim),
run on a *named scenario* from the registry so the experiment is
reproducible by preset name + overrides (``sim/scenarios.py``).

Run:  PYTHONPATH=src python examples/mobility_speedup.py
"""
import sys

sys.path.insert(0, "benchmarks")
from paper_benches import tab_smart_update  # noqa: E402

# the interference-limited "dense_urban" preset, scaled to the paper's
# mobility experiment (10% of UEs teleport per step); the smart update
# recomputes only the dirtied rows either way -- the preset just pins the
# physics (UMi at 3.5 GHz, per-RB fading, tri-sector sites)
name, us, speedup = tab_smart_update(n_ues=2000, n_cells=201, frac=0.10,
                                     n_steps=8, scenario="dense_urban")
print(f"{name} [dense_urban]: smart step {us/1e3:.1f} ms -> "
      f"speed-up x{speedup:.2f} at 10% mobility "
      f"(paper claims ~x2; results numerically identical)")
