"""Paper example 13: smart update vs full recalculation (the x2 claim).

Run:  PYTHONPATH=src python examples/mobility_speedup.py
"""
import sys

sys.path.insert(0, "benchmarks")
from paper_benches import tab_smart_update  # noqa: E402

name, us, speedup = tab_smart_update()
print(f"{name}: smart step {us/1e3:.1f} ms -> speed-up x{speedup:.2f} "
      f"at 10% mobility (paper claims ~x2; results numerically identical)")
