"""Paper example 13: smart update vs full recalculation — on BOTH surfaces.

1. The paper's original host-driven experiment: move 10% of UEs, re-query
   the graph; dirty-row caching (``core/blocks.py``) recomputes only the
   dirtied rows (the paper's ~x2 claim), on a *named scenario* from the
   registry so the experiment is reproducible by preset name + overrides.
2. The same compute-on-demand idea inside the compiled TTI engine
   (DESIGN.md §Smart-update-in-scan): a ``lax.scan`` episode where 10% of
   UEs walk per TTI, rolled once densely (full D..SE recompute per TTI)
   and once with ``radio_mode="incremental"`` (dirty rows only) —
   identical trajectories, one compiled program each, no per-step Python.

Run:  PYTHONPATH=src python examples/mobility_speedup.py
"""
import sys
import time

import jax
import numpy as np

sys.path.insert(0, "benchmarks")
from paper_benches import tab_smart_update  # noqa: E402

from repro.core.crrm import CRRM  # noqa: E402
from repro.sim.scenarios import make_scenario  # noqa: E402

# -- 1. the graph path (host-driven mutate/query, the paper's experiment) --
# the interference-limited "dense_urban" preset, scaled to the paper's
# mobility experiment (10% of UEs teleport per step); the smart update
# recomputes only the dirtied rows either way -- the preset just pins the
# physics (UMi at 3.5 GHz, per-RB fading, tri-sector sites)
name, us, speedup = tab_smart_update(n_ues=2000, n_cells=201, frac=0.10,
                                     n_steps=8, scenario="dense_urban")
print(f"{name} [dense_urban]: smart step {us/1e3:.1f} ms -> "
      f"speed-up x{speedup:.2f} at 10% mobility "
      f"(paper claims ~x2; results numerically identical)")

# -- 2. the scan path (compiled episodes, ISSUE-5 smart update in-scan) ----
# the digital-twin preset bakes the regime in: mobility_move_frac=0.1,
# radio_mode="incremental"; here we shrink it and roll the SAME episode
# densely vs incrementally to show the in-engine speed-up + equivalence
p_kw = dict(n_ues=5000, n_cells=57, n_sectors=1)
N_TTI = 40
key = jax.random.PRNGKey(0)


def roll(radio_mode):
    sim = CRRM(make_scenario("dense_urban_twin", radio_mode=radio_mode,
                             **p_kw))
    fns = sim.episode_fns()
    static, state = sim.episode_static(), sim.init_episode_state(key)
    out = fns.rollout(static, state, N_TTI)          # compile + warm
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    out = fns.rollout(static, state, N_TTI)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / N_TTI * 1e3, np.asarray(out[1])


ms_dense, t_dense = roll("dense")
ms_inc, t_inc = roll("incremental")
rel = float(np.abs(t_inc - t_dense).max() / max(np.abs(t_dense).max(), 1.0))
assert rel < 1e-5, f"incremental != dense ({rel:.2e})"
print(f"smart_update_in_scan [dense_urban_twin {p_kw['n_ues']} UEs x "
      f"{N_TTI} TTIs, 10% movers/TTI]: dense {ms_dense:.1f} ms/TTI, "
      f"incremental {ms_inc:.1f} ms/TTI -> x{ms_dense/ms_inc:.2f} "
      f"(max rel err {rel:.1e} -- same trajectory, compiled end-to-end)")
